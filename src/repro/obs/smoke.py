"""CI telemetry smoke: a real engine run end-to-end through the
telemetry stack.

::

    python -m repro.obs.smoke [--out-dir DIR] [--rounds N]

Runs two schemes with ``telemetry="jsonl"`` — one synchronous, one
semi-async, so both round loops are exercised — then, per run:

1. validates the ``events.jsonl`` artifact against the schema-1
   validator (:mod:`repro.obs.schema`);
2. exports and re-loads the Perfetto/Chrome ``trace_event`` JSON;
3. renders the ``repro.obs.report`` summary;
4. re-runs the identical config with ``telemetry="off"`` and asserts
   the histories are **identical** — telemetry must never change the
   simulation.

Exits non-zero on any failure; prints the report text so the CI log
shows what a run summary looks like.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import tempfile
from pathlib import Path

RUNS = (
    {"scheme": "heroes", "round_mode": "sync"},
    {"scheme": "fedavg", "round_mode": "semi_async"},
)


def _cfg(round_mode: str, rounds: int, **kw):
    from repro.fl.types import FLConfig

    return FLConfig(num_clients=10, clients_per_round=4, eval_every=2,
                    tau_fixed=4, tau_max=15, estimate=True,
                    round_mode=round_mode, **kw)


def _run(scheme: str, cfg, rounds: int):
    from repro.fl.simulation import build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=cfg.num_clients,
                                            seed=0)
    with build_runner(scheme, model, px, py, test, cfg=cfg) as runner:
        hist = runner.run(rounds)
    return [dataclasses.asdict(h) for h in hist]


def smoke_one(scheme: str, round_mode: str, out_dir: Path,
              rounds: int) -> None:
    from repro.obs.report import render_report
    from repro.obs.schema import validate_file
    from repro.obs.sinks import load_events
    from repro.obs.trace import export_trace

    run_dir = out_dir / f"{scheme}_{round_mode}"
    print(f"\n=== smoke: scheme={scheme} round_mode={round_mode} "
          f"({rounds} rounds) ===")
    hist_on = _run(scheme, _cfg(round_mode, rounds, telemetry="jsonl",
                                telemetry_dir=str(run_dir)), rounds)

    events_path = run_dir / "events.jsonl"
    counts = validate_file(events_path)
    print(f"schema OK: {counts}")
    if not counts.get("span"):
        raise AssertionError("telemetry run recorded no spans")
    if counts.get("metrics") != 1:
        raise AssertionError("missing final metrics snapshot")

    events = load_events(events_path)
    trace_path = export_trace(events, run_dir / "trace.json")
    trace = json.loads(trace_path.read_text(encoding="utf-8"))
    if not isinstance(trace.get("traceEvents"), list) \
            or not trace["traceEvents"]:
        raise AssertionError("trace_event export has no traceEvents")
    n_complete = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
    print(f"trace_event OK: {len(trace['traceEvents'])} events "
          f"({n_complete} complete spans)")

    print(render_report(events))

    hist_off = _run(scheme, _cfg(round_mode, rounds, telemetry="off"),
                    rounds)
    if hist_on != hist_off:
        raise AssertionError(
            "telemetry=jsonl changed the run history vs telemetry=off")
    print("history parity OK: telemetry on == off "
          f"({len(hist_on)} rounds, bitwise)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="End-to-end telemetry smoke over two engine runs")
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: a temp dir)")
    ap.add_argument("--rounds", type=int, default=4)
    args = ap.parse_args(argv)
    out_dir = Path(args.out_dir) if args.out_dir \
        else Path(tempfile.mkdtemp(prefix="obs_smoke_"))
    out_dir.mkdir(parents=True, exist_ok=True)
    for run in RUNS:
        smoke_one(run["scheme"], run["round_mode"], out_dir, args.rounds)
    print(f"\ntelemetry smoke passed; artifacts under {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
