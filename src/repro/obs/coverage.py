"""Per-tensor (per-block) training-coverage metrics.

Heroes' motivating observation (paper Fig. 2 / Sec. I) is that naive
neural composition trains some low-rank coefficient blocks with only a
small fraction of clients, starving the largest sub-model.  The
assignment policies record two dense tallies per block family:

``coverage.hidden_rounds`` / ``coverage.anchored_rounds``
    how many *assignment events* (rounds for the sync loop, dispatches
    for the semi-async loop) included each hidden-layer / anchored-layer
    block in at least one client's assignment — the Fig. 2 quantity
    once divided by ``coverage.events``;
``coverage.hidden_iters`` / ``coverage.anchored_iters``
    the tau-weighted training-iteration totals per block (the Heroes
    scheduler's own counter signal, mirrored into telemetry so every
    scheme reports it, not just Heroes).

This module turns a metrics snapshot into that normalized table.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

FAMILIES = ("hidden", "anchored")


def coverage_table(metrics: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Per-family coverage from a metrics snapshot.

    Returns ``{family: {"events": E, "rounds": [...], "iters": [...],
    "coverage": [r / E, ...], "min": ..., "max": ..., "mean": ...}}``
    for every block family with a recorded tally.  ``coverage[b]`` is
    the fraction of assignment events in which block ``b`` was trained
    by at least one client.
    """
    tallies = metrics.get("tallies", {})
    counters = metrics.get("counters", {})
    events = int(counters.get("coverage.events", 0))
    out: Dict[str, Dict[str, Any]] = {}
    for fam in FAMILIES:
        rounds: Optional[List[float]] = tallies.get(f"coverage.{fam}_rounds")
        if rounds is None:
            continue
        iters = tallies.get(f"coverage.{fam}_iters", [0] * len(rounds))
        cov = [(r / events if events else 0.0) for r in rounds]
        out[fam] = {
            "events": events,
            "rounds": [int(r) for r in rounds],
            "iters": [int(v) for v in iters],
            "coverage": cov,
            "min": min(cov) if cov else 0.0,
            "max": max(cov) if cov else 0.0,
            "mean": (sum(cov) / len(cov)) if cov else 0.0,
        }
    return out


def format_coverage(table: Dict[str, Dict[str, Any]],
                    bar_width: int = 24) -> str:
    """Render a coverage table as aligned text with unit-interval bars."""
    if not table:
        return "(no coverage tallies recorded — dense scheme or no " \
               "assignment events)"
    lines: List[str] = []
    for fam, t in table.items():
        lines.append(f"{fam} blocks — trained in fraction of "
                     f"{t['events']} assignment events "
                     f"(min {t['min']:.2f} / mean {t['mean']:.2f} / "
                     f"max {t['max']:.2f}):")
        for b, (c, it) in enumerate(zip(t["coverage"], t["iters"])):
            bar = "#" * int(round(c * bar_width))
            lines.append(f"  block {b:3d}  {c:6.2%}  "
                         f"|{bar:<{bar_width}}|  {it:6d} iters")
    return "\n".join(lines)
