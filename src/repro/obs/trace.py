"""Perfetto / Chrome ``trace_event`` export for recorded spans.

The recorder's span stream maps onto the Trace Event Format's complete
events (``"ph": "X"``), which both ``chrome://tracing`` and
https://ui.perfetto.dev open directly:

* **virtual-clock** spans land under pid 1 (``virtual-clock``), one
  track (tid) per client id — so a run renders as the paper's Gantt
  view: every sampled client's train→upload bar in simulated time;
* **wall-clock** spans land under pid 2 (``host``), one track per span
  name (merge latency, host staging, device steps, checkpoint writes).

Timestamps are microseconds (virtual seconds and perf_counter seconds
both scale by 1e6); point events become instants (``"ph": "i"``).

CLI::

    python -m repro.obs.trace run_dir/events.jsonl trace.json
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List

_PID_VIRTUAL = 1
_PID_WALL = 2


def _meta_event(pid: int, tid: int, name: str, kind: str) -> Dict[str, Any]:
    return {"name": kind, "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}}


class _Tracks:
    """Stable tid assignment per (pid, track-name)."""

    def __init__(self):
        self._ids: Dict[tuple, int] = {}
        self.meta: List[Dict[str, Any]] = []

    def tid(self, pid: int, name: str) -> int:
        key = (pid, name)
        if key not in self._ids:
            tid = len(self._ids) + 1
            self._ids[key] = tid
            self.meta.append(_meta_event(pid, tid, name, "thread_name"))
        return self._ids[key]


def to_trace_events(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Convert a recorded event list to a ``trace_event`` JSON object."""
    tracks = _Tracks()
    out: List[Dict[str, Any]] = [
        _meta_event(_PID_VIRTUAL, 0, "virtual-clock", "process_name"),
        _meta_event(_PID_WALL, 0, "host", "process_name"),
    ]
    meta_args: Dict[str, Any] = {}
    for e in events:
        t = e.get("type")
        if t == "meta":
            meta_args = {k: v for k, v in e.items() if k != "type"}
            continue
        if t not in ("span", "event"):
            continue
        virtual = e.get("clock") == "virtual"
        pid = _PID_VIRTUAL if virtual else _PID_WALL
        attrs = e.get("attrs", {})
        if virtual and "client" in attrs:
            track = f"client {attrs['client']}"
        else:
            track = e["name"]
        tid = tracks.tid(pid, track)
        if t == "span":
            out.append({"name": e["name"], "ph": "X", "pid": pid, "tid": tid,
                        "ts": e["t0"] * 1e6,
                        "dur": max(e["t1"] - e["t0"], 0.0) * 1e6,
                        "cat": e["clock"], "args": attrs})
        else:
            out.append({"name": e["name"], "ph": "i", "pid": pid, "tid": tid,
                        "ts": e["t"] * 1e6, "s": "t",
                        "cat": e["clock"], "args": attrs})
    return {"traceEvents": out + tracks.meta,
            "displayTimeUnit": "ms",
            "otherData": meta_args}


def export_trace(events: List[Dict[str, Any]], out_path: str | Path) -> Path:
    """Write the ``trace_event`` JSON for ``events``; returns the path."""
    out_path = Path(out_path)
    out_path.write_text(json.dumps(to_trace_events(events)) + "\n",
                        encoding="utf-8")
    return out_path


def main(argv=None) -> int:
    import argparse

    from repro.obs.sinks import load_events

    ap = argparse.ArgumentParser(
        description="Export a telemetry JSONL log as Perfetto/Chrome "
                    "trace_event JSON")
    ap.add_argument("events", help="path to events.jsonl")
    ap.add_argument("out", help="output trace JSON path")
    args = ap.parse_args(argv)
    path = export_trace(load_events(args.events), args.out)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
