"""Telemetry core: a metrics registry plus a span tracer.

One :class:`Recorder` serves a whole run.  It owns

* a **metrics registry** — counters (monotonic sums), gauges (last
  value), histograms (raw observation lists) and **tallies** (dense
  integer arrays indexed by block id — the per-tensor coverage
  primitive: Heroes' per-block training counts land here), and
* a **span stream** — interval events over either the run's *virtual*
  clock (simulated seconds: dispatch→train→upload per client) or the
  *wall* clock (``time.perf_counter``: merge latency, host staging,
  device steps, checkpoint writes) — fanned out to pluggable
  :mod:`~repro.obs.sinks`.

The registry mutates under one lock (the cohort trainer's prefetch
worker records host-staging timings off the main thread); the event
stream is append-only through the same lock.

:class:`NoopRecorder` — the ``FLConfig.telemetry="off"`` default — is a
true no-op: every method is an empty override, ``enabled`` is False so
hot paths can skip even argument construction, and instrumented code
paths stay bitwise-identical to uninstrumented ones (telemetry never
draws RNG, never touches jax values, only *reads* the quantities the
engine already computed).

Metric names are dotted strings; labels are folded into the registry
key as ``name[k=v,...]`` (sorted), so a labelled counter family needs
no separate declaration.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

SCHEMA_VERSION = 1


def metric_key(name: str, labels: Dict[str, Any]) -> str:
    """Canonical registry key: ``name`` or ``name[k=v,...]`` (sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}[{inner}]"


class _NullCtx:
    """Reusable do-nothing context manager (NoopRecorder.wall_span)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _WallSpan:
    """Context manager recording one wall-clock span on exit."""

    __slots__ = ("rec", "name", "attrs", "t0")

    def __init__(self, rec: "Recorder", name: str, attrs: Dict[str, Any]):
        self.rec = rec
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.rec.span(self.name, self.t0, t1, clock="wall", **self.attrs)
        self.rec.observe(f"{self.name}_s", t1 - self.t0)
        return False


class Recorder:
    """Live telemetry: metrics registry + span stream over sinks."""

    enabled = True

    def __init__(self, sinks: Iterable[Any] = (),
                 meta: Optional[Dict[str, Any]] = None):
        self._lock = threading.Lock()
        self.sinks = list(sinks)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, List[float]] = {}
        self.tallies: Dict[str, np.ndarray] = {}
        self._closed = False
        if meta is not None:
            self._emit({"type": "meta", "schema": SCHEMA_VERSION, **meta})

    # -- event stream -------------------------------------------------------

    def _emit(self, obj: Dict[str, Any]) -> None:
        with self._lock:
            for s in self.sinks:
                s.emit(obj)

    def span(self, name: str, t0: float, t1: float, *,
             clock: str = "virtual", **attrs) -> None:
        """One interval event.  ``clock="virtual"`` times are simulated
        seconds (the engine's virtual clock); ``"wall"`` times are
        ``time.perf_counter`` seconds."""
        self._emit({"type": "span", "name": name, "clock": clock,
                    "t0": float(t0), "t1": float(t1), "attrs": attrs})

    def event(self, name: str, t: float, *, clock: str = "virtual",
              **attrs) -> None:
        """One point event on the given clock."""
        self._emit({"type": "event", "name": name, "clock": clock,
                    "t": float(t), "attrs": attrs})

    def wall_span(self, name: str, **attrs):
        """``with rec.wall_span("aggregate.merge"): ...`` — records the
        span on the wall clock plus a ``<name>_s`` histogram entry."""
        return _WallSpan(self, name, attrs)

    # -- metrics registry ---------------------------------------------------

    def counter_add(self, name: str, value: float = 1.0, **labels) -> None:
        key = metric_key(name, labels)
        with self._lock:
            self.counters[key] = self.counters.get(key, 0.0) + float(value)

    def gauge_set(self, name: str, value: float, **labels) -> None:
        key = metric_key(name, labels)
        with self._lock:
            self.gauges[key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        key = metric_key(name, labels)
        with self._lock:
            self.histograms.setdefault(key, []).append(float(value))

    def tally_add(self, name: str, ids, amount=1) -> None:
        """Add ``amount`` (scalar or per-id array) at ``ids`` of the
        named dense tally, growing it as needed (``np.add.at`` handles
        repeated ids)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size == 0:
            return
        need = int(ids.max()) + 1
        amt = np.asarray(amount, np.int64)
        with self._lock:
            cur = self.tallies.get(name)
            if cur is None:
                cur = np.zeros(need, np.int64)
            elif cur.size < need:
                cur = np.concatenate(
                    [cur, np.zeros(need - cur.size, np.int64)])
            np.add.at(cur, ids, amt)
            self.tallies[name] = cur

    # -- lifecycle ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready view of the metrics registry."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: list(v)
                               for k, v in self.histograms.items()},
                "tallies": {k: v.tolist() for k, v in self.tallies.items()},
            }

    def flush(self) -> None:
        with self._lock:
            for s in self.sinks:
                s.flush()

    def close(self) -> None:
        """Emit the final metrics snapshot and close every sink.

        Idempotent — the engine runner calls it from ``close()`` and the
        context-manager exit."""
        if self._closed:
            return
        self._closed = True
        self._emit({"type": "metrics", **self.snapshot()})
        with self._lock:
            for s in self.sinks:
                s.close()


class NoopRecorder(Recorder):
    """The ``telemetry="off"`` recorder: every operation is a no-op.

    A singleton (:data:`NOOP`) shared by every disabled run — it holds
    no state, so sharing is safe.  ``enabled`` is False so hot loops can
    skip argument construction entirely."""

    enabled = False

    def __init__(self):  # no lock, no sinks, no registries
        self.sinks = []
        self.counters = {}
        self.gauges = {}
        self.histograms = {}
        self.tallies = {}

    def span(self, *a, **kw) -> None:
        pass

    def event(self, *a, **kw) -> None:
        pass

    def wall_span(self, *a, **kw):
        return _NULL_CTX

    def counter_add(self, *a, **kw) -> None:
        pass

    def gauge_set(self, *a, **kw) -> None:
        pass

    def observe(self, *a, **kw) -> None:
        pass

    def tally_add(self, *a, **kw) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": {}, "gauges": {}, "histograms": {},
                "tallies": {}}

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NOOP = NoopRecorder()


def runtime_provenance() -> Dict[str, Any]:
    """Environment fingerprint stamped into telemetry metas and the
    ``BENCH_*.json`` entries: what machine/toolchain produced a number.

    Never raises — every probe degrades to ``"unknown"`` so benchmarks
    and telemetry work outside a git checkout or without jax devices.
    """
    import os
    import platform
    import subprocess

    prov: Dict[str, Any] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax

        prov["jax"] = jax.__version__
        devs = jax.local_devices()
        prov["device_kind"] = devs[0].device_kind if devs else "none"
        prov["device_count"] = len(devs)
    except Exception:  # pragma: no cover - jax init failure
        prov["jax"] = "unknown"
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        prov["git_sha"] = sha.stdout.strip() if sha.returncode == 0 \
            else "unknown"
    except Exception:  # pragma: no cover - no git binary
        prov["git_sha"] = "unknown"
    return prov


def build_recorder(cfg, meta: Optional[Dict[str, Any]] = None) -> Recorder:
    """Recorder per ``FLConfig.telemetry``:

    ``"off"``
        the shared :data:`NOOP` instance (default — zero overhead,
        instrumented paths bitwise-identical to uninstrumented ones);
    ``"memory"``
        a :class:`Recorder` over one in-memory sink (tests, notebooks);
    ``"jsonl"``
        a :class:`Recorder` appending every event to
        ``<cfg.telemetry_dir>/events.jsonl`` (``telemetry_dir``
        required), with the final metrics snapshot written at close.
    """
    mode = getattr(cfg, "telemetry", "off") or "off"
    if mode == "off":
        return NOOP
    meta = dict(meta or {})
    meta.setdefault("provenance", runtime_provenance())
    if mode == "memory":
        from repro.obs.sinks import MemorySink

        return Recorder([MemorySink()], meta=meta)
    if mode == "jsonl":
        from repro.obs.sinks import JsonlSink

        tdir = getattr(cfg, "telemetry_dir", None)
        if not tdir:
            raise ValueError(
                "FLConfig.telemetry='jsonl' requires telemetry_dir")
        from pathlib import Path

        path = Path(tdir)
        path.mkdir(parents=True, exist_ok=True)
        return Recorder([JsonlSink(path / "events.jsonl")], meta=meta)
    raise ValueError(f"unknown telemetry mode {mode!r}; "
                     "expected 'off', 'memory' or 'jsonl'")
