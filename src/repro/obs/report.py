"""Run-summary CLI over a telemetry JSONL artifact.

::

    python -m repro.obs.report run_dir/events.jsonl [--trace out.json]

Prints the quantities the baselines in PAPERS.md report but this repo
previously could not extract from a run: the per-block coverage table
(paper Fig. 2), the staleness histogram (semi-async), the up/down
traffic breakdown per assigned width, per-capacity-class participation,
jit-recompile counts, and wall-time summaries of the instrumented host
stages.  ``--trace`` additionally writes the Perfetto/Chrome
``trace_event`` export of the span stream.
"""

from __future__ import annotations

import argparse
import re
from typing import Any, Dict, List, Optional

from repro.obs.coverage import coverage_table, format_coverage

_LBL = re.compile(r"^(?P<name>[^\[]+)\[(?P<labels>.*)\]$")


def split_key(key: str):
    """``name[k=v,...]`` -> (name, {k: v}); plain names pass through."""
    m = _LBL.match(key)
    if not m:
        return key, {}
    labels = {}
    for part in m.group("labels").split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            labels[k] = v
    return m.group("name"), labels


def labelled(counters: Dict[str, float], name: str) -> Dict[str, float]:
    """All ``name[...]`` counter values keyed by their label string."""
    out = {}
    for k, v in counters.items():
        base, labels = split_key(k)
        if base == name:
            out[",".join(f"{a}={b}" for a, b in sorted(labels.items()))] = v
    return out


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024.0 or unit == "TB":
            return f"{b:.1f} {unit}"
        b /= 1024.0
    return f"{b:.1f} TB"  # pragma: no cover


def histogram_lines(values: List[float], bins: int = 8,
                    bar_width: int = 24, integer: bool = False) -> List[str]:
    """Fixed-width text histogram of raw observations."""
    if not values:
        return ["  (no observations)"]
    lo, hi = min(values), max(values)
    if integer:
        edges = [lo + i for i in range(int(hi - lo) + 2)]
    elif lo == hi:
        edges = [lo, hi + 1e-12]
    else:
        step = (hi - lo) / bins
        edges = [lo + i * step for i in range(bins + 1)]
    counts = [0] * (len(edges) - 1)
    for v in values:
        for i in range(len(counts)):
            if v < edges[i + 1] or i == len(counts) - 1:
                counts[i] += 1
                break
    peak = max(counts)
    out = []
    for i, c in enumerate(counts):
        if integer:
            label = f"{int(edges[i])}"
        else:
            label = f"[{edges[i]:.3g}, {edges[i + 1]:.3g})"
        bar = "#" * (int(round(c / peak * bar_width)) if peak else 0)
        out.append(f"  {label:>16}  {c:6d}  |{bar}")
    return out


def _find_metrics(events: List[Dict[str, Any]]) -> Optional[Dict[str, Any]]:
    for e in reversed(events):
        if e.get("type") == "metrics":
            return e
    return None


def render_report(events: List[Dict[str, Any]]) -> str:
    """The full text report for one event log."""
    lines: List[str] = []
    meta = events[0] if events and events[0].get("type") == "meta" else {}
    scheme = meta.get("scheme", "?")
    cfg = meta.get("config", {})
    lines.append(f"== repro.obs run report — scheme={scheme} "
                 f"round_mode={cfg.get('round_mode', '?')} "
                 f"trainer={cfg.get('trainer', '?')} ==")
    prov = meta.get("provenance", {})
    if prov:
        lines.append(f"   jax {prov.get('jax', '?')} on "
                     f"{prov.get('device_count', '?')}x "
                     f"{prov.get('device_kind', '?')} "
                     f"(git {str(prov.get('git_sha', '?'))[:12]})")

    metrics = _find_metrics(events)
    if metrics is None:
        spans = sum(1 for e in events if e.get("type") == "span")
        lines.append(f"\n{len(events)} events ({spans} spans); no final "
                     "metrics snapshot — run was killed before close(); "
                     "span stream only.")
        return "\n".join(lines)
    counters = metrics.get("counters", {})
    hists = metrics.get("histograms", {})

    lines.append("\n-- per-block coverage (paper Fig. 2 quantity) --")
    lines.append(format_coverage(coverage_table(metrics)))

    lines.append("\n-- traffic --")
    up = labelled(counters, "traffic.up")
    down = labelled(counters, "traffic.down")
    total_up, total_down = sum(up.values()), sum(down.values())
    lines.append(f"uplink   {_fmt_bytes(total_up):>12}")
    lines.append(f"downlink {_fmt_bytes(total_down):>12}")
    for lbl in sorted(set(up) | set(down)):
        lines.append(f"  {lbl or '(unlabelled)':>12}: "
                     f"up {_fmt_bytes(up.get(lbl, 0.0))}, "
                     f"down {_fmt_bytes(down.get(lbl, 0.0))}")

    lines.append("\n-- participation by capacity class --")
    tiers = labelled(counters, "participation.tier")
    if tiers:
        total = sum(tiers.values())
        for lbl, v in sorted(tiers.items(), key=lambda kv: -kv[1]):
            lines.append(f"  {lbl:>20}: {int(v):6d} ({v / total:6.2%})")
    else:
        lines.append("  (none recorded)")

    lines.append("\n-- staleness (semi-async merges) --")
    stale = hists.get("staleness", [])
    if stale:
        lines.append(f"  {len(stale)} merged results, "
                     f"{sum(1 for s in stale if s > 0)} stale")
        lines.extend(histogram_lines(stale, integer=True))
    else:
        lines.append("  (no staleness observations — synchronous run)")

    lines.append("\n-- compiled-step cache --")
    rec_map = labelled(counters, "trainer.jit_recompiles")
    rec = sum(rec_map.values()) + counters.get("trainer.jit_recompiles", 0)
    shapes = len(labelled(counters, "trainer.cohort_shape"))
    lines.append(f"  train-step recompiles: {int(rec)}"
                 + (f" over {shapes} distinct cohort shapes" if shapes
                    else ""))
    for lbl, v in sorted(rec_map.items()):
        lines.append(f"    {lbl}: {int(v)}")

    lines.append("\n-- host wall time (instrumented stages) --")
    stage_names = sorted(k for k in hists if k.endswith("_s"))
    if not stage_names:
        lines.append("  (none recorded)")
    for k in stage_names:
        v = hists[k]
        lines.append(f"  {k[:-2]:>24}: n={len(v):4d}  total="
                     f"{sum(v):8.3f}s  mean={sum(v) / len(v):8.4f}s  "
                     f"max={max(v):8.4f}s")

    ckpt = counters.get("checkpoint.bytes")
    if ckpt:
        lines.append(f"\ncheckpoints: "
                     f"{int(counters.get('checkpoint.saves', 0))} saves, "
                     f"{_fmt_bytes(ckpt)} written")
    return "\n".join(lines)


def main(argv=None) -> int:
    from repro.obs.sinks import load_events

    ap = argparse.ArgumentParser(
        description="Summarize a repro.obs telemetry JSONL artifact")
    ap.add_argument("events", help="path to events.jsonl")
    ap.add_argument("--trace", default=None,
                    help="also write the Perfetto trace_event export here")
    args = ap.parse_args(argv)
    events = load_events(args.events)
    print(render_report(events))
    if args.trace:
        from repro.obs.trace import export_trace

        path = export_trace(events, args.trace)
        print(f"\nwrote trace_event export: {path}")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
