"""Pluggable telemetry sinks.

A sink receives every event the :class:`~repro.obs.recorder.Recorder`
emits — the ``meta`` header, ``span``/``event`` stream entries, and the
final ``metrics`` snapshot at close (see :mod:`repro.obs.schema` for
the event shapes).  Sinks are called under the recorder's lock, so they
need no synchronisation of their own.

``MemorySink`` keeps everything in a list (tests, notebooks);
``JsonlSink`` appends one JSON object per line, write-through, so a run
killed mid-flight still leaves a readable prefix (only the final
``metrics`` line is lost).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional


class Sink:
    """Sink contract: ``emit`` every event, ``flush``/``close`` once."""

    def emit(self, obj: Dict[str, Any]) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Everything in a list — the test/notebook sink.

    ``spans(name)`` / ``events_named(name)`` are the common query
    helpers; ``metrics`` holds the final snapshot after close.
    """

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self.metrics: Optional[Dict[str, Any]] = None

    def emit(self, obj: Dict[str, Any]) -> None:
        self.events.append(obj)
        if obj.get("type") == "metrics":
            self.metrics = obj

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [e for e in self.events if e.get("type") == "span"
                and (name is None or e["name"] == name)]

    def events_named(self, name: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e.get("type") == "event"
                and e["name"] == name]


class JsonlSink(Sink):
    """One JSON object per line, appended write-through.

    The file handle opens lazily on the first event and is line-buffered
    by explicit ``flush`` at close; a crashed run leaves every event up
    to the crash on disk (missing only the final metrics snapshot —
    :mod:`repro.obs.report` degrades gracefully in that case).
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._fh = None

    def emit(self, obj: Dict[str, Any]) -> None:
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("a", encoding="utf-8")
        self._fh.write(json.dumps(obj) + "\n")

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            self._fh.close()
            self._fh = None


def load_events(path: str | Path) -> List[Dict[str, Any]]:
    """Parse a JSONL event log back into a list of event dicts.

    Tolerates a truncated final line (a run killed mid-write) by
    dropping it — every complete line parses or the error propagates.
    """
    out: List[Dict[str, Any]] = []
    lines = Path(path).read_text(encoding="utf-8").splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:  # torn tail from a killed writer
                break
            raise
    return out
