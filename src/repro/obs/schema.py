"""JSONL event-log schema (version 1) + validator.

Every line of ``events.jsonl`` is one JSON object with a ``type``:

``meta``
    First line of a run.  ``{"type": "meta", "schema": 1, "scheme":
    str, "config": {...}, "provenance": {...}}`` — the config summary
    and environment fingerprint the run was produced under.
``span``
    ``{"type": "span", "name": str, "clock": "virtual"|"wall",
    "t0": num, "t1": num >= t0, "attrs": {...}}`` — an interval on the
    virtual clock (simulated seconds: per-client train/upload) or the
    wall clock (perf_counter seconds: merges, staging, device steps,
    checkpoint writes).
``event``
    ``{"type": "event", "name": str, "clock": ..., "t": num,
    "attrs": {...}}`` — a point on either clock.
``metrics``
    Last line of a clean run: the final registry snapshot —
    ``{"type": "metrics", "counters": {str: num}, "gauges":
    {str: num}, "histograms": {str: [num]}, "tallies": {str: [int]}}``.

The validator is deliberately dependency-free (no jsonschema): the CI
telemetry-smoke leg runs it over a real engine run's artifact.
"""

from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, List

_CLOCKS = ("virtual", "wall")


def _fail(i: int, msg: str) -> None:
    raise ValueError(f"event {i}: {msg}")


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_event(obj: Dict[str, Any], i: int = 0) -> None:
    """Raise ``ValueError`` unless ``obj`` is a valid schema-1 event."""
    if not isinstance(obj, dict):
        _fail(i, f"not an object: {type(obj).__name__}")
    t = obj.get("type")
    if t == "meta":
        if obj.get("schema") != 1:
            _fail(i, f"unsupported schema version {obj.get('schema')!r}")
    elif t == "span":
        if not isinstance(obj.get("name"), str):
            _fail(i, "span without a string name")
        if obj.get("clock") not in _CLOCKS:
            _fail(i, f"bad clock {obj.get('clock')!r}")
        if not (_num(obj.get("t0")) and _num(obj.get("t1"))):
            _fail(i, "span t0/t1 must be numbers")
        if obj["t1"] < obj["t0"]:
            _fail(i, f"span ends before it starts ({obj['t0']}..{obj['t1']})")
        if not isinstance(obj.get("attrs"), dict):
            _fail(i, "span attrs must be an object")
    elif t == "event":
        if not isinstance(obj.get("name"), str):
            _fail(i, "event without a string name")
        if obj.get("clock") not in _CLOCKS:
            _fail(i, f"bad clock {obj.get('clock')!r}")
        if not _num(obj.get("t")):
            _fail(i, "event t must be a number")
        if not isinstance(obj.get("attrs"), dict):
            _fail(i, "event attrs must be an object")
    elif t == "metrics":
        for section, leaf in (("counters", _num), ("gauges", _num)):
            d = obj.get(section)
            if not isinstance(d, dict):
                _fail(i, f"metrics.{section} must be an object")
            for k, v in d.items():
                if not leaf(v):
                    _fail(i, f"metrics.{section}[{k!r}] is not a number")
        for section in ("histograms", "tallies"):
            d = obj.get(section)
            if not isinstance(d, dict):
                _fail(i, f"metrics.{section} must be an object")
            for k, v in d.items():
                if not isinstance(v, list) or not all(_num(x) for x in v):
                    _fail(i, f"metrics.{section}[{k!r}] is not a number list")
    else:
        _fail(i, f"unknown event type {t!r}")


def validate_events(events: List[Dict[str, Any]]) -> Dict[str, int]:
    """Validate a whole event list; returns per-type counts.

    Beyond per-event shape: the first event must be the ``meta`` header
    and at most one ``metrics`` snapshot may appear (as the last line).
    """
    if not events:
        raise ValueError("empty event log")
    if events[0].get("type") != "meta":
        raise ValueError("first event is not the meta header")
    counts: Dict[str, int] = {}
    for i, e in enumerate(events):
        validate_event(e, i)
        counts[e["type"]] = counts.get(e["type"], 0) + 1
    if counts.get("metrics", 0) > 1:
        raise ValueError(f"{counts['metrics']} metrics snapshots (expect <=1)")
    if counts.get("metrics") and events[-1].get("type") != "metrics":
        raise ValueError("metrics snapshot is not the final event")
    return counts


def validate_file(path: str | Path) -> Dict[str, int]:
    """Validate an ``events.jsonl`` artifact; returns per-type counts."""
    from repro.obs.sinks import load_events

    return validate_events(load_events(path))
