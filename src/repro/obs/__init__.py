"""repro.obs — structured telemetry for the FL engine.

A metrics registry (counters / gauges / histograms / per-block tallies)
plus a span tracer over the simulation's **virtual clock** and the host
wall clock, fanned out to pluggable sinks (in-memory, JSONL,
Perfetto/Chrome ``trace_event`` export).  Off by default
(``FLConfig.telemetry="off"`` routes every call to the no-op
:data:`NOOP` recorder); when enabled, instrumented runs stay
bitwise-identical to uninstrumented ones — telemetry only *reads*
quantities the engine already computed.

Entry points::

    python -m repro.obs.report run_dir/events.jsonl   # run summary
    python -m repro.obs.trace  run_dir/events.jsonl t.json  # Perfetto
    python -m repro.obs.smoke                          # CI end-to-end

See ``docs/OBSERVABILITY.md`` for the metric catalog.
"""

from repro.obs.coverage import coverage_table, format_coverage
from repro.obs.recorder import (NOOP, NoopRecorder, Recorder, build_recorder,
                                metric_key, runtime_provenance)
from repro.obs.schema import validate_event, validate_events, validate_file
from repro.obs.sinks import JsonlSink, MemorySink, Sink, load_events
from repro.obs.trace import export_trace, to_trace_events

__all__ = [
    "Recorder", "NoopRecorder", "NOOP", "build_recorder", "metric_key",
    "runtime_provenance",
    "Sink", "MemorySink", "JsonlSink", "load_events",
    "validate_event", "validate_events", "validate_file",
    "to_trace_events", "export_trace",
    "coverage_table", "format_coverage",
]
