"""Msgpack checkpointing for param/optimizer pytrees.

Layout: a directory per step (``step_00000120/state.msgpack``) holding a
flattened { "path/to/leaf": {dtype, shape, data} } map plus a manifest.
Works for any nested dict/list/tuple pytree of jax or numpy arrays;
restores onto host then (optionally) device_puts with a given sharding.

Writes are atomic at the step-directory level: the payload is staged
into a ``step_XXXXXXXX.tmp.<pid>`` sibling and renamed into place with
``os.replace`` once fully written, so an interrupted save never leaves a
partial ``step_*`` directory for ``restore_latest`` to trip over (stale
``.tmp`` leftovers are ignored by the strict step pattern and swept on
the next successful save).
"""

from __future__ import annotations

import json
import os
import re
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def _list_steps(directory: Path) -> List[Tuple[int, Path]]:
    """(step, path) pairs for complete checkpoints, ascending by step.

    Numeric sort on the strict ``step_<digits>`` pattern, so staging
    ``.tmp`` directories and unrelated entries are never candidates and
    unpadded step names still order correctly.
    """
    steps = []
    for p in directory.iterdir():
        m = _STEP_RE.match(p.name)
        if m and p.is_dir():
            steps.append((int(m.group(1)), p))
    return sorted(steps)


def save_checkpoint(directory: str | Path, step: int, state: Any,
                    keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat = _flatten(jax.device_get(state))
    payload = {}
    for k, v in flat.items():
        dtype = str(v.dtype)
        if v.dtype == jnp.bfloat16:
            v = v.view(np.uint16)
            dtype = "bfloat16"
        payload[k] = {"dtype": dtype, "shape": list(v.shape),
                      "data": v.tobytes()}
    blob = msgpack.packb(payload)  # serialize before touching disk
    path = directory / f"step_{step:08d}"
    tmp = directory / f"{path.name}.tmp.{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    try:
        (tmp / "state.msgpack").write_bytes(blob)
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "leaves": len(payload)}))
        if path.exists():
            shutil.rmtree(path)
        os.replace(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # prune old checkpoints + any stale staging dirs from dead writers
    for _, old in _list_steps(directory)[:-keep]:
        shutil.rmtree(old)
    for stale in directory.glob("step_*.tmp.*"):
        if stale != tmp:
            shutil.rmtree(stale, ignore_errors=True)
    return path


def load_checkpoint(path: str | Path) -> Any:
    path = Path(path)
    payload = msgpack.unpackb((path / "state.msgpack").read_bytes())
    flat = {}
    for k, meta in payload.items():
        key = k.decode() if isinstance(k, bytes) else k
        dtype = meta[b"dtype"] if b"dtype" in meta else meta["dtype"]
        dtype = dtype.decode() if isinstance(dtype, bytes) else dtype
        shape = meta[b"shape"] if b"shape" in meta else meta["shape"]
        data = meta[b"data"] if b"data" in meta else meta["data"]
        if dtype == "bfloat16":
            arr = np.frombuffer(data, np.uint16).reshape(shape).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(data, np.dtype(dtype)).reshape(shape)
        flat[key] = arr
    return _unflatten(flat)


def restore_latest(directory: str | Path) -> Optional[tuple]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = _list_steps(directory)
    if not steps:
        return None
    step, last = steps[-1]
    return step, load_checkpoint(last)
