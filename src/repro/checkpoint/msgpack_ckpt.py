"""Msgpack checkpointing for param/optimizer pytrees.

Layout: a directory per step (``step_000120/state.msgpack``) holding a
flattened { "path.to.leaf": {dtype, shape, data} } map plus a manifest.
Works for any nested dict/list/tuple pytree of jax or numpy arrays;
restores onto host then (optionally) device_puts with a given sharding.
"""

from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


_DTYPE_FIX = {"V2": "bfloat16"}  # numpy void16 <- bf16 roundtrip


def save_checkpoint(directory: str | Path, step: int, state: Any,
                    keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    path = directory / f"step_{step:08d}"
    path.mkdir(exist_ok=True)
    flat = _flatten(jax.device_get(state))
    payload = {}
    for k, v in flat.items():
        dtype = str(v.dtype)
        if v.dtype == jnp.bfloat16:
            v = v.view(np.uint16)
            dtype = "bfloat16"
        payload[k] = {"dtype": dtype, "shape": list(v.shape),
                      "data": v.tobytes()}
    (path / "state.msgpack").write_bytes(msgpack.packb(payload))
    (path / "manifest.json").write_text(json.dumps(
        {"step": step, "leaves": len(payload)}))
    # prune old
    steps = sorted(directory.glob("step_*"))
    for old in steps[:-keep]:
        for f in old.iterdir():
            f.unlink()
        old.rmdir()
    return path


def load_checkpoint(path: str | Path) -> Any:
    path = Path(path)
    payload = msgpack.unpackb((path / "state.msgpack").read_bytes())
    flat = {}
    for k, meta in payload.items():
        key = k.decode() if isinstance(k, bytes) else k
        dtype = meta[b"dtype"] if b"dtype" in meta else meta["dtype"]
        dtype = dtype.decode() if isinstance(dtype, bytes) else dtype
        shape = meta[b"shape"] if b"shape" in meta else meta["shape"]
        data = meta[b"data"] if b"data" in meta else meta["data"]
        if dtype == "bfloat16":
            arr = np.frombuffer(data, np.uint16).reshape(shape).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(data, np.dtype(dtype)).reshape(shape)
        flat[key] = arr
    return _unflatten(flat)


def restore_latest(directory: str | Path) -> Optional[tuple]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(directory.glob("step_*"))
    if not steps:
        return None
    last = steps[-1]
    step = int(re.search(r"step_(\d+)", last.name).group(1))
    return step, load_checkpoint(last)
