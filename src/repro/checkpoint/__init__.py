from repro.checkpoint.msgpack_ckpt import (  # noqa: F401
    load_checkpoint,
    restore_latest,
    save_checkpoint,
)
