"""Sharding rules: param-tree paths -> PartitionSpec.

Conventions (per-pod mesh ("data", "model") = (16, 16); multi-pod adds a
leading "pod" axis used for data parallelism and — where memory demands,
e.g. kimi-k2 — extra parameter sharding):

  * "column-parallel" projections (d -> heads/ff):  (..., d, out)  ->  ('data', 'model')
    — model parallelism over heads/FFN, ZeRO-style FSDP over the d rows.
  * "row-parallel" projections (heads/ff -> d):     (..., in, d)   ->  ('model', 'data')
  * expert tensors (E, d, f):                        E -> 'model' (expert
    parallel), d -> 'data' (FSDP).
  * embeddings (V, d): vocab -> 'model', d -> 'data'.
  * small/1D tensors (norms, biases, gates): replicated.

Rules are keyed by path *suffix* of the UNSTACKED weight; any extra leading
stack axes (layer scan: 1 extra; hybrid/xlstm superblocks: 2 extra) are
padded with ``None``.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# ordered (regex on dotted path, base spec for trailing dims)
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # --- embeddings -----------------------------------------------------
    (r"(embed|unembed)\.table$", ("model", "data")),
    # --- attention projections ------------------------------------------
    (r"(attn|self_attn|cross_attn)\.w[qkv]\.w$", ("data", "model")),
    (r"(attn|self_attn|cross_attn)\.wo\.w$", ("model", "data")),
    # factorized (Heroes composition) projections
    (r"\.w[qkvo]\.basis$", ("data", None)),
    (r"\.w[qkvo]\.coeff$", (None, None, "model")),
    # --- dense MLP -------------------------------------------------------
    (r"mlp\.(gate|up)\.w$", ("data", "model")),
    (r"mlp\.down\.w$", ("model", "data")),
    (r"mlp\.(gate|up)\.basis$", ("data", None)),
    (r"mlp\.(gate|up)\.coeff$", (None, None, "model")),
    (r"mlp\.down\.basis$", ("model", None)),
    (r"mlp\.down\.coeff$", (None, None, "data")),
    # --- MoE ---------------------------------------------------------------
    (r"moe.*router\.w$", ("data", None)),
    (r"moe.*\.(gate|up)$", ("model", "data", None)),
    (r"moe.*\.down$", ("model", None, "data")),
    (r"shared\.(gate|up)\.w$", ("data", "model")),
    (r"shared\.down\.w$", ("model", "data")),
    # --- Mamba2 -----------------------------------------------------------
    (r"in_proj\.w$", ("data", "model")),
    (r"out_proj\.w$", ("model", "data")),
    (r"in_proj\.basis$", ("data", None)),
    (r"in_proj\.coeff$", (None, None, "model")),
    (r"out_proj\.basis$", ("model", None)),
    (r"out_proj\.coeff$", (None, None, "data")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"(A_log|D|dt_bias)$", (None,)),
    # --- xLSTM --------------------------------------------------------------
    (r"(up|wq|wk|wv|ff_up)\.w$", ("data", "model")),
    (r"(down|ff_down)\.w$", ("model", "data")),
    (r"(up|wq|wk|wv|ff_up)\.basis$", ("data", None)),
    (r"(up|wq|wk|wv|ff_up)\.coeff$", (None, None, "model")),
    (r"(down|ff_down)\.basis$", ("model", None)),
    (r"(down|ff_down)\.coeff$", (None, None, "data")),
    (r"wif\.w$", ("data", None)),
    (r"wx\.w$", ("data", "model")),
    (r"\br$", ("model", None, None)),
    (r"skip$", (None,)),
    (r"bias$", (None,)),
    # --- norms / scalars ------------------------------------------------
    (r"(ln1|ln2|ln_x|norm|out_norm|gn|final_norm)\.(scale|bias)$", (None,)),
)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit_to_shape(spec: P, shape, mesh) -> P:
    """Drop sharding on any dim the mesh axis doesn't divide."""
    if mesh is None:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, parts):
        out.append(axis if axis is not None and dim % _axis_size(mesh, axis) == 0
                   else None)
    return P(*out)


def _spec_for(path: str, ndim: int) -> P:
    for pat, base in _RULES:
        if re.search(pat, path):
            pad = ndim - len(base)
            if pad < 0:  # rule longer than array (e.g. squeezed) — replicate
                return P()
            return P(*([None] * pad), *base)
    return P()  # default: replicate


def param_specs(params: Any, mesh=None, zero_pod: bool = False,
                moe_ep: bool = False) -> Any:
    """PartitionSpec tree mirroring ``params``.

    mesh: when given, any sharded dim the mesh axis size doesn't divide
      falls back to replication for that dim.
    zero_pod: additionally shard the largest tensors over the 'pod' axis
      (ZeRO across pods) — used by the trillion-param config.
    moe_ep: weight-stationary expert parallelism — expert tensors shard
      ONLY over 'model' (no FSDP on the data axis), matching the
      shard_map EP schedule (repro.models.moe_shardmap).
    """

    def f(path, leaf):
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        spec = _spec_for(name, leaf.ndim)
        if moe_ep and re.search(r"moe.*\.(gate|up|down)$", name):
            spec = P(*([None] * (leaf.ndim - 3)), "model", None, None)
        if zero_pod:
            spec = _add_pod(spec, leaf)
        return _fit_to_shape(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(f, params)


def _add_pod(spec: P, leaf) -> P:
    """Fold the pod axis into the first already-sharded dim (making it a
    tuple axis) for big tensors; small tensors stay pod-replicated."""
    if leaf.size < (1 << 20):
        return spec
    parts = list(spec)
    for i, s in enumerate(parts):
        if s == "model":
            dim = leaf.shape[i]
            if dim % (16 * 2) == 0:
                parts[i] = ("pod", "model")
                return P(*parts)
    for i, s in enumerate(parts):
        if s == "data":
            dim = leaf.shape[i]
            if dim % (16 * 2) == 0:
                parts[i] = ("pod", "data")
                return P(*parts)
    return spec


def batch_specs(batch_tree: Any, dp_axes, mesh=None) -> Any:
    """Shard every batch leaf's leading (batch) dim over the data axes.
    Falls back to fewer/no axes when the batch doesn't divide (long_500k
    has global_batch=1 — necessarily replicated)."""

    def f(leaf):
        axes = dp_axes
        if mesh is not None:
            b = leaf.shape[0]
            if b % _axis_size(mesh, axes) != 0:
                if isinstance(axes, tuple):  # try dropping the pod axis
                    for sub in (axes[1:], None):
                        if sub is None or b % _axis_size(mesh, tuple(sub)) == 0:
                            axes = tuple(sub) if sub else None
                            break
                else:
                    axes = None
        return P(axes, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(f, batch_tree)


def cache_specs(cache_tree: Any, cfg, dp_axes, mesh=None) -> Any:
    """KV / recurrent cache sharding for decode.

    Stacked KV caches are (L, B, S, KV, D): batch -> data axes, kv heads ->
    'model' when they divide the axis; otherwise model-replicated.
    Recurrent (mamba/xlstm) states are (stack..., B, ...): batch -> data.
    """

    def f(path, leaf):
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if re.search(r"(k_scale|v_scale)$", name) and leaf.ndim == 4:
            # int8-cache scales (L, B, S, KV): mirror the cache layout
            if leaf.shape[3] % 16 == 0:
                spec = P(None, dp_axes, None, "model")
            else:
                spec = P(None, dp_axes, "model", None)
            return _fit_to_shape(spec, leaf.shape, mesh)
        if re.search(r"(^|\.)(k|v|mem_k|mem_v)$", name) and leaf.ndim == 5:
            # (L, B, S, KV, D): shard kv heads over 'model' when they
            # divide; otherwise shard the cache length S (GSPMD handles the
            # softmax over the sharded axis with a psum) — this is what
            # keeps 32k/500k caches of MQA/GQA<16 archs within HBM.
            if leaf.shape[3] % 16 == 0:
                spec = P(None, dp_axes, None, "model", None)
            else:
                spec = P(None, dp_axes, "model", None, None)
        elif re.search(r"mem_mask$", name):
            spec = P(dp_axes, None)
        else:
            spec = _cache_state_spec(name, leaf, dp_axes)
        return _fit_to_shape(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(f, cache_tree)


def _cache_state_spec(name: str, leaf, dp_axes) -> P:
    # mamba: cache["mamba"]["conv"]: (nsuper, per, B, W, C) / ["state"]:
    # (nsuper, per, B, H, N, P).  xlstm similar.  encdec handled above.
    if re.search(r"mamba\.(conv|state)", name):
        pad = leaf.ndim - 1
        if "state" in name:
            return P(None, None, dp_axes, "model", None, None)
        return P(None, None, dp_axes, None, "model")
    if re.search(r"mlstm\.(C|n|m|conv)", name):
        base = {"C": (None, None, dp_axes, "model", None, None),
                "n": (None, None, dp_axes, "model", None),
                "m": (None, None, dp_axes, "model"),
                "conv": (None, None, dp_axes, None, "model")}
        leafname = name.split(".")[-1]
        return P(*base[leafname])
    if re.search(r"slstm\.(c|n|h|m)$", name):
        return P(None, dp_axes, "model", None)
    return P()


def dp_axes_for(mesh) -> Any:
    """Data-parallel axes tuple for a mesh: ('pod','data') when multi-pod."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else "data"
