"""FL aggregation sharding: the cohort device mesh + coefficient layouts.

The engine's collective merge lays *clients* out on a 1-D mesh axis
(``COHORT_AXIS``): each device folds its local shard of the stacked
contributions in order, then a ``psum`` combines the partial sums
(repro.core.aggregation.masked_block_merge).  The same axis doubles as
the *block* axis for the merged coefficient when the server state is
sharded (``FLConfig.shard_server_state``): after the psum every device
keeps its contiguous slice of the ``P^2`` block dimension, so the global
coefficient tensor never needs to be replicated.

All helpers degrade to ``None``/no-ops on a single device — the engine
then uses the compiled single-device fallback, which is bitwise-equal to
the host scatter loop.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

COHORT_AXIS = "cohort"


def cohort_mesh(max_devices: int = 0) -> Optional[Mesh]:
    """1-D mesh over the *local* devices, or ``None`` when only one exists.

    ``max_devices > 0`` caps the mesh (useful to pin tests to a size);
    0 means all local devices.  The mesh deliberately uses
    ``jax.local_devices()``: under multi-process JAX, ``jax.devices()``
    also lists devices other hosts own, and a mesh over those would try
    to place client shards this process cannot address.
    """
    devs = jax.local_devices()
    if max_devices > 0:
        devs = devs[:max_devices]
    if len(devs) < 2:
        return None
    return Mesh(np.array(devs), (COHORT_AXIS,))


def contribution_spec() -> P:
    """Layout of stacked client contributions: client axis on the mesh."""
    return P(COHORT_AXIS)


def replicated_spec() -> P:
    return P()


def block_spec() -> P:
    """Block-axis-sharded layout for the merged coefficient tensor."""
    return P(COHORT_AXIS)


def pad_cohort(k: int, mesh: Optional[Mesh]) -> int:
    """Padded client count: next multiple of the mesh size (1 device: k)."""
    if mesh is None:
        return k
    n = mesh.devices.size
    return ((k + n - 1) // n) * n


def can_shard_blocks(num_blocks: int, mesh: Optional[Mesh]) -> bool:
    """Block sharding needs the block axis divisible by the mesh."""
    return mesh is not None and num_blocks % mesh.devices.size == 0


def client_axis_spec(axis: int) -> P:
    """Spec for an array whose client axis sits at position ``axis``."""
    return P(*((None,) * axis + (COHORT_AXIS,)))


def assemble_from_host_shards(shards, mesh: Mesh, axis: int = 0):
    """Global device array from per-device *host* shards, no host concat.

    ``shards`` holds one numpy chunk per mesh device, split along
    ``axis`` (the client axis).  Each chunk is transferred straight to
    its device and the results are stitched into one array sharded
    ``P(..., COHORT_AXIS, ...)`` — the layout the sharded cohort step
    and the collective merge both consume, so a monolithic stacked copy
    never exists on either side.
    """
    devs = list(mesh.devices.flat)
    if len(shards) != len(devs):
        raise ValueError(f"{len(shards)} shards for {len(devs)} devices")
    spec = client_axis_spec(axis)
    arrays = [jax.device_put(np.ascontiguousarray(s), d)
              for s, d in zip(shards, devs)]
    shape = list(shards[0].shape)
    shape[axis] = sum(s.shape[axis] for s in shards)
    return jax.make_array_from_single_device_arrays(
        tuple(shape), jax.sharding.NamedSharding(mesh, spec), arrays)
