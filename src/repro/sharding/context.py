"""Activation-sharding context.

Model code is mesh-agnostic; the launcher installs a context (mesh + the
data-parallel axes + a residual-stream layout) and the model calls
``constrain(x, kind)`` at layer boundaries.  Without a context the call is
a no-op (CPU tests / FL simulation).

Residual layouts (the §Perf hillclimb toggles these):
  "d_sharded"   (dp, None, 'model')  — hidden dim sharded (baseline)
  "seq_sharded" (dp, 'model', None)  — Megatron-style sequence parallelism
  "replicated"  (dp, None, None)
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = {"mesh": None, "dp": None, "residual": "d_sharded",
        "attn_qseq": False, "moe_shardmap": False}


def set_context(mesh, dp_axes, residual: str = "d_sharded",
                attn_qseq: bool = False, moe_shardmap: bool = False) -> None:
    _CTX.update(mesh=mesh, dp=dp_axes, residual=residual,
                attn_qseq=attn_qseq, moe_shardmap=moe_shardmap)


def clear_context() -> None:
    _CTX.update(mesh=None, dp=None, residual="d_sharded", attn_qseq=False,
                moe_shardmap=False)


def get_context() -> dict:
    return dict(_CTX)


@contextlib.contextmanager
def activation_sharding(mesh, dp_axes, residual: str = "d_sharded"):
    prev = dict(_CTX)
    set_context(mesh, dp_axes, residual)
    try:
        yield
    finally:
        _CTX.update(prev)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(spec_parts, shape, mesh):
    out = []
    for dim, axis in zip(shape, list(spec_parts) + [None] * (len(shape) - len(spec_parts))):
        ok = axis is not None and dim % _axis_size(mesh, axis) == 0
        out.append(axis if ok else None)
    return P(*out)


def constrain_attention_q(q, k, v):
    """Context-parallel attention layout (§Perf iteration): shard the
    QUERY sequence over the model axis and replicate k/v over it, so the
    flash-attention block loops compute fully locally — k/v are gathered
    once per layer instead of being resharded per (q-chunk, kv-chunk)
    block.  Correctness is untouched (causal masking sees the full k/v).

    q: (B, S, KV, G, D); k/v: (B, S, KV, D).
    """
    mesh = _CTX["mesh"]
    if mesh is None or not _CTX["attn_qseq"] or q.shape[1] <= 1:
        return q, k, v
    dp = _CTX["dp"]
    qspec = _fit((dp, "model", None, None, None), q.shape, mesh)
    kvspec = _fit((dp, None, None, None), k.shape, mesh)
    q = jax.lax.with_sharding_constraint(q, NamedSharding(mesh, qspec))
    k = jax.lax.with_sharding_constraint(k, NamedSharding(mesh, kvspec))
    v = jax.lax.with_sharding_constraint(v, NamedSharding(mesh, kvspec))
    return q, k, v


def constrain_residual(x):
    """Apply the configured residual-stream layout to a (B, S, d) tensor."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    dp = _CTX["dp"]
    layout = {
        "d_sharded": (dp, None, "model"),
        "seq_sharded": (dp, "model", None),
        "replicated": (dp, None, None),
    }[_CTX["residual"]]
    spec = _fit(layout, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
