from repro.sharding.rules import (  # noqa: F401
    batch_specs,
    cache_specs,
    param_specs,
)
from repro.sharding.fl import (  # noqa: F401
    COHORT_AXIS,
    block_spec,
    can_shard_blocks,
    cohort_mesh,
    contribution_spec,
    pad_cohort,
    replicated_spec,
)
